"""GraphProcess layer (core/graphs.py): every realized A_t satisfies the
Assumption-1 invariants (symmetric, doubly stochastic, inside the base
support), StaticGraph is bit-identical to the pre-redesign baked-A path for
every preset, graph_state checkpoints and restores, the adaptive consensus
gamma derives from the spectral gap and anneals from the observed
contraction, and third-party graph kinds register end-to-end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import GRAPHS, GraphSpec, build
from repro.core import (CommPipeline, DiffusionConfig, DiffusionEngine,
                        GossipMatching, LinkDropout, StaticGraph,
                        TimeVaryingErdos, choco_gamma, make_graph_process,
                        make_mixer, make_pipeline, make_topology, mix_dense)
from repro.core import participation as part
from repro.core import topology as topo_lib
from repro.core import variants
from repro.data.synthetic import make_block_sampler, make_regression_problem

KEY = jax.random.PRNGKey(0)
K = 6


# ---------------------------------------------------------------------------
# property gates: every realized A_t is a valid combination matrix
# ---------------------------------------------------------------------------

def _processes(topo):
    return [
        StaticGraph(topo),
        LinkDropout(topo, drop=0.0),
        LinkDropout(topo, drop=0.3),
        LinkDropout(topo, drop=0.7, corr=0.6),
        GossipMatching(topo),
        TimeVaryingErdos(topo.num_agents, p=0.3),
    ]


@pytest.mark.parametrize("kind,n", [("ring", 8), ("grid", 12),
                                    ("erdos", 10)])
def test_realized_matrices_symmetric_doubly_stochastic(kind, n):
    """Acceptance gate: every A_t from every process is symmetric, doubly
    stochastic, nonnegative — the eq.-20 invariants survive any draw."""
    topo = make_topology(kind, n)
    for proc in _processes(topo):
        state = proc.init_state(jax.random.fold_in(KEY, 7))
        for i in range(12):
            A_t, state = proc.sample(state, jax.random.fold_in(KEY, i))
            A = np.asarray(A_t, np.float64)
            assert topo_lib.is_symmetric(A, tol=1e-5), proc
            assert topo_lib.is_doubly_stochastic(A, tol=1e-5), proc
            assert (A >= -1e-6).all(), proc


@pytest.mark.parametrize("kind,n", [("ring", 8), ("grid", 12)])
def test_dynamic_support_stays_on_base_adjacency(kind, n):
    """LinkDropout / GossipMatching never put weight on a non-edge of the
    base graph (the sparse circulant backend relies on this)."""
    topo = make_topology(kind, n)
    non_edge = ~np.asarray(topo.adjacency)
    for proc in (LinkDropout(topo, drop=0.4),
                 LinkDropout(topo, drop=0.4, corr=0.5),
                 GossipMatching(topo)):
        assert proc.within_base_support
        state = proc.init_state(jax.random.fold_in(KEY, 3))
        for i in range(10):
            A_t, state = proc.sample(state, jax.random.fold_in(KEY, 50 + i))
            assert np.abs(np.asarray(A_t)[non_edge]).max() == 0.0, proc


def test_link_dropout_zero_drop_is_static_metropolis():
    """drop = 0 keeps every link: the realized matrix equals the base
    Metropolis weights every block."""
    topo = make_topology("ring", 8)
    proc = LinkDropout(topo, drop=0.0)
    for i in range(4):
        A_t, _ = proc.sample((), jax.random.fold_in(KEY, i))
        np.testing.assert_allclose(np.asarray(A_t),
                                   topo.A.astype(np.float32), atol=1e-6)


def test_link_dropout_stationary_up_frequency():
    """The per-link up-frequency converges to 1 - drop, with and without
    temporal correlation (the Markov chain's stationary law)."""
    topo = make_topology("ring", 8)
    base_off = np.asarray(topo.adjacency & ~np.eye(8, dtype=bool))
    for corr in (0.0, 0.6):
        proc = LinkDropout(topo, drop=0.3, corr=corr)
        state = proc.init_state(jax.random.PRNGKey(1))
        up_counts = np.zeros((8, 8))
        steps = 1500
        for i in range(steps):
            A_t, state = proc.sample(state, jax.random.fold_in(KEY, i))
            up_counts += np.asarray(A_t) > 0
        freq = up_counts[base_off] / steps
        np.testing.assert_allclose(freq, 0.7, atol=0.06,
                                   err_msg=f"corr={corr}")


def test_gossip_matching_is_a_matching():
    """Every realized gossip matrix pairs each agent with at most one
    neighbor (degree <= 1 in the matched off-diagonal support)."""
    topo = make_topology("ring", 9)
    proc = GossipMatching(topo)
    matched_any = False
    for i in range(20):
        A_t, _ = proc.sample((), jax.random.fold_in(KEY, i))
        A = np.asarray(A_t)
        off_deg = (A > 0).sum(axis=1) - 1
        assert off_deg.max() <= 1
        if off_deg.max() == 1:
            matched_any = True
            # matched pairs average 1/2-1/2; unmatched agents hold
            matched = np.where(off_deg == 1)[0]
            np.testing.assert_allclose(np.diag(A)[matched], 0.5, atol=1e-6)
            unmatched = np.where(off_deg == 0)[0]
            np.testing.assert_allclose(np.diag(A)[unmatched], 1.0,
                                       atol=1e-6)
    assert matched_any


def test_tv_erdos_rejects_sparse_mixer_and_auto_falls_back():
    topo = make_topology("ring", 8)
    cfg = DiffusionConfig(num_agents=8, topology="ring", graph="tv_erdos",
                          graph_kwargs=(("p", 0.4),), mix="sparse")
    data = make_regression_problem(K=8, N=20)
    with pytest.raises(ValueError, match="circulant"):
        DiffusionEngine(cfg, data.loss_fn())
    # "auto" resolves away from sparse instead of dying
    eng = DiffusionEngine(dataclasses.replace(cfg, mix="auto"),
                          data.loss_fn())
    assert not isinstance(eng.mixer,
                          __import__("repro.core.mixing",
                                     fromlist=["x"]).SparseCirculantMixer)
    # and the engine actually runs
    sampler = make_block_sampler(data, T=1, batch=1)
    st = eng.init_state(jnp.zeros((8, 2)))
    st, _ = eng.step(st, sampler(KEY), jax.random.PRNGKey(1))
    assert np.isfinite(np.asarray(st.params)).all()


def test_sharded_builder_without_topology_fails_loudly():
    """A forgotten topology must not silently train with A_t = I (zero
    communication); mixers that ignore the matrix (robust / none) still
    build against an inert identity, as before the redesign."""
    from repro.core.sharded import make_block_step
    loss3 = lambda p, b, rng: 0.0
    with pytest.raises(ValueError, match="topology"):
        make_block_step(loss3, DiffusionConfig(num_agents=8))
    s = make_block_step(loss3, DiffusionConfig(num_agents=8,
                                               mix="trimmed_mean"))
    assert s.graph.num_agents == 8
    s = make_block_step(loss3, DiffusionConfig(num_agents=1, mix="none"))
    assert s.graph.num_agents == 1


def test_make_graph_process_factory_and_validation():
    topo = make_topology("ring", 6)
    assert isinstance(make_graph_process("static", topo), StaticGraph)
    proc = make_graph_process("link_dropout", topo, drop=0.2, corr=0.1)
    assert isinstance(proc, LinkDropout) and proc.stateful
    assert not make_graph_process("link_dropout", topo, drop=0.2).stateful
    assert isinstance(make_graph_process("gossip", topo), GossipMatching)
    assert isinstance(make_graph_process("tv_erdos", None, num_agents=6),
                      TimeVaryingErdos)
    assert make_graph_process(proc) is proc          # passthrough
    with pytest.raises(ValueError):
        make_graph_process("nope", topo)
    with pytest.raises(ValueError):
        make_graph_process("gossip", None)
    with pytest.raises(ValueError):
        LinkDropout(topo, drop=1.0)
    with pytest.raises(ValueError):
        TimeVaryingErdos(6, p=0.0)


# ---------------------------------------------------------------------------
# StaticGraph == pre-redesign baked-A path, bit for bit, for every preset
# ---------------------------------------------------------------------------

def _baked_dense_mixer(A):
    """The PRE-REDESIGN DenseMixer: the matrix frozen at construction,
    per-call A_t ignored — the baseline the runtime-topology path must
    reproduce bit-for-bit when the graph is static."""
    from repro.core import mixing

    class BakedDense(mixing.Mixer):
        def __init__(self, A):
            self.A = jnp.asarray(A, jnp.float32)

        def __call__(self, params, active, A_t=None):
            return mix_dense(part.masked_combination(self.A, active),
                             params)

    return BakedDense(A)


@pytest.mark.parametrize("name", sorted([
    "fedavg_full", "fedavg_partial_uniform", "vanilla_diffusion",
    "asynchronous_diffusion", "decentralized_fedavg", "cyclic_fedavg",
    "markov_asynchronous_diffusion", "compressed_diffusion",
    "compressed_fedavg"]))
def test_static_graph_bit_identical_to_baked_A(name):
    """Acceptance gate: GraphSpec(kind="static") runs are bit-identical to
    the pre-redesign baked-A path for every preset — the engine with a
    mixer that froze A at construction (the old contract) produces
    array_equal outputs against the runtime-A_t engine."""
    factories = {
        "fedavg_full": lambda: variants.fedavg_full(K, T=3, mu=0.02),
        "fedavg_partial_uniform":
            lambda: variants.fedavg_partial_uniform(K, T=2, mu=0.05, q=0.6),
        "vanilla_diffusion": lambda: variants.vanilla_diffusion(K, mu=0.05),
        "asynchronous_diffusion":
            lambda: variants.asynchronous_diffusion(K, mu=0.03, q=0.6),
        "decentralized_fedavg":
            lambda: variants.decentralized_fedavg(K, T=4, mu=0.02),
        "cyclic_fedavg":
            lambda: variants.cyclic_fedavg(K, T=2, mu=0.02, num_groups=3),
        "markov_asynchronous_diffusion":
            lambda: variants.markov_asynchronous_diffusion(K, mu=0.02,
                                                           q=0.6, corr=0.5),
        "compressed_diffusion":
            lambda: variants.compressed_diffusion(K, mu=0.02, T=2, q=0.8,
                                                  compress="topk",
                                                  ratio=0.5),
        "compressed_fedavg":
            lambda: variants.compressed_fedavg(K, T=2, mu=0.02, q=0.8),
    }
    spec = factories[name]()
    assert spec.graph == GraphSpec(kind="static")
    data = make_regression_problem(K=K, N=40, M=2, rho=0.1, seed=1)
    eng_runtime = build(spec, data.loss_fn())
    assert isinstance(eng_runtime.graph, StaticGraph)
    cfg = spec.to_diffusion_config()
    eng_baked = DiffusionEngine(
        cfg, data.loss_fn(),
        mixer=_baked_dense_mixer(cfg.make_topology().A),
        participation=eng_runtime.process if cfg.graph == "static" else None)

    T = spec.run.local_steps
    sampler = make_block_sampler(data, T=T, batch=1)
    params = jax.random.normal(jax.random.PRNGKey(0), (K, 2))
    key0 = jax.random.fold_in(jax.random.PRNGKey(3), 0x5EED)
    s_rt = eng_runtime.init_state(params, key=key0)
    s_bk = eng_baked.init_state(params, key=key0)
    assert s_rt.graph_state is None          # static graphs carry nothing
    for i in range(4):
        batch = sampler(jax.random.PRNGKey(100 + i))
        k = jax.random.PRNGKey(200 + i)
        s_rt, m_rt = eng_runtime.step(s_rt, batch, k)
        s_bk, m_bk = eng_baked.step(s_bk, batch, k)
        np.testing.assert_array_equal(np.asarray(m_rt["active"]),
                                      np.asarray(m_bk["active"]))
        np.testing.assert_array_equal(np.asarray(s_rt.params),
                                      np.asarray(s_bk.params))


# ---------------------------------------------------------------------------
# engine threading + checkpoint round trip of graph_state
# ---------------------------------------------------------------------------

def test_engine_threads_graph_state_and_converges():
    """End-to-end: link dropout at drop=0.3 on a ring still converges (the
    acceptance regime of bench_graph_process), threading the link mask
    through EngineState.graph_state."""
    n = 8
    data = make_regression_problem(K=n, N=60, M=2, rho=0.1, seed=0)
    spec = variants.link_dropout_diffusion(n, mu=0.02, drop=0.3, corr=0.5,
                                           T=2, q=0.9)
    eng = build(spec, data.loss_fn())
    assert eng.graph.stateful
    w_o = data.problem().w_opt(np.full(n, 0.9))
    sampler = make_block_sampler(data, T=2, batch=1)
    params = jnp.full((n, 2), 3.0)
    _, _, hist = eng.run(params, sampler, 400, seed=0,
                         w_star=jnp.asarray(w_o))
    assert np.mean(hist[-50:]) < 0.05 * hist[0]


def test_sharded_step_threads_graph_state():
    from repro.core.sharded import make_block_step
    n = 6
    data = make_regression_problem(K=n, N=40, M=2, rho=0.1, seed=3)
    cfg = DiffusionConfig(num_agents=n, local_steps=2, step_size=0.02,
                          topology="ring", participation=0.9,
                          graph="link_dropout",
                          graph_kwargs=(("corr", 0.5), ("drop", 0.3)))
    topo = cfg.make_topology()
    loss3 = lambda p, b, rng: data.loss_fn()(p, b)
    block_step = make_block_step(loss3, cfg, topology=topo)
    step = jax.jit(block_step)
    sampler = make_block_sampler(data, T=2, batch=1)
    state = block_step.init_state(jnp.zeros((n, 2)),
                                  key=jax.random.PRNGKey(4))
    assert state.graph_state is not None
    masks = []
    for i in range(3):
        state, _ = step(state, sampler(jax.random.PRNGKey(10 + i)),
                        jax.random.PRNGKey(i))
        masks.append(np.asarray(state.graph_state))
    assert any(not np.array_equal(a, b)
               for a, b in zip(masks, masks[1:]))   # links actually churn
    # a stateful graph fed graph_state=None fails loudly
    from repro.core import EngineState
    with pytest.raises(ValueError, match="init_state"):
        step(EngineState(jnp.zeros((n, 2))),
             sampler(jax.random.PRNGKey(0)), jax.random.PRNGKey(0))


def test_checkpoint_roundtrip_graph_state(tmp_path):
    """graph_state rides the EngineState checkpoint: restore rebuilds the
    exact engine and continues bit-identically."""
    from repro.checkpoint import load_experiment, load_spec, save_experiment
    n = K
    data = make_regression_problem(K=n, N=40, M=2, rho=0.1, seed=0)
    spec = variants.link_dropout_diffusion(n, mu=0.02, drop=0.4, corr=0.5,
                                           T=2, q=0.8)
    eng = build(spec, data.loss_fn())
    params = jax.random.normal(jax.random.PRNGKey(0), (n, 2))
    state = eng.init_state(params, key=jax.random.PRNGKey(1))
    sampler = make_block_sampler(data, T=2, batch=1)
    for i in range(3):
        state, _ = eng.step(state, sampler(jax.random.PRNGKey(10 + i)),
                            jax.random.PRNGKey(i))
    assert state.graph_state is not None

    path = str(tmp_path / "graph_ckpt.npz")
    save_experiment(path, state, spec=spec, step=3)
    spec2 = load_spec(path)
    assert spec2 == spec and spec2.graph.kind == "link_dropout"
    eng2 = build(spec2, data.loss_fn())
    like = eng2.init_state(jnp.zeros_like(params),
                           key=jax.random.PRNGKey(9))
    restored, meta = load_experiment(path, like)
    np.testing.assert_array_equal(np.asarray(restored.graph_state),
                                  np.asarray(state.graph_state))
    batch = sampler(jax.random.PRNGKey(99))
    k = jax.random.PRNGKey(7)
    s1, _ = eng.step(state, batch, k)
    s2, _ = eng2.step(restored, batch, k)
    np.testing.assert_array_equal(np.asarray(s1.params),
                                  np.asarray(s2.params))
    np.testing.assert_array_equal(np.asarray(s1.graph_state),
                                  np.asarray(s2.graph_state))


# ---------------------------------------------------------------------------
# adaptive consensus gamma (comm_gamma="auto")
# ---------------------------------------------------------------------------

def test_choco_gamma_formula_properties():
    """The CHOCO step grows with the spectral gap and the compressor
    contraction, and stays in (0, 1]."""
    assert 0 < choco_gamma(0.1, 0.1, 2.0) < choco_gamma(0.5, 0.1, 2.0) <= 1
    assert choco_gamma(0.2, 0.1, 1.5) < choco_gamma(0.2, 0.9, 1.5)


def test_adaptive_gamma_floor_from_spectral_gap():
    """gamma="auto" derives its floor from spectral_gap(A) — no hard-coded
    0.5/ratio value — and requires the base matrix."""
    topo = make_topology("ring", 8)
    pipe = make_pipeline("dense", topo, compress="topk", compress_ratio=0.1,
                         gamma="auto")
    assert pipe.adaptive and pipe.gamma == "auto"
    rho = topo_lib.spectral_gap(topo.A)
    beta = 1.0 - np.linalg.eigvalsh(topo.A).min()
    assert pipe.spectral_gap == pytest.approx(rho)
    assert pipe.gamma_floor == pytest.approx(choco_gamma(rho, 0.1, beta))
    state = pipe.init_state({"w": jnp.zeros((8, 4))})
    assert float(state["delta"]) == pytest.approx(0.1)
    # denser graph (larger gap) -> larger floor
    full = make_topology("fedavg", 8)
    pipe_full = make_pipeline("dense", full, compress="topk",
                              compress_ratio=0.1, gamma="auto")
    assert pipe_full.gamma_floor > pipe.gamma_floor
    with pytest.raises(ValueError, match="spectral gap"):
        CommPipeline(make_mixer("dense", topo),
                     __import__("repro.core.compression",
                                fromlist=["x"]).TopK(0.1), gamma="auto")


def test_adaptive_gamma_anneals_from_observed_contraction():
    """On a fixed signal the diff-mode reference tracks psi, the observed
    contraction EMA rises, and the annealed gamma climbs from the CHOCO
    floor toward 1 — while A_t keeps flowing as an operand."""
    topo = make_topology("ring", 8)
    A = jnp.asarray(topo.A, jnp.float32)
    pipe = make_pipeline("dense", topo, compress="topk", compress_ratio=0.25,
                         gamma="auto")
    params = {"w": jax.random.normal(KEY, (8, 16))}
    state = pipe.init_state(params)
    g0 = float(pipe.annealed_gamma(state))
    assert g0 == pytest.approx(pipe.gamma_floor
                               + (1 - pipe.gamma_floor) * 0.5)  # sqrt(0.25)
    gammas = [g0]
    m = jnp.ones((8,))
    for i in range(15):
        _, state = pipe(params, m, A, state, jax.random.fold_in(KEY, i))
        gammas.append(float(pipe.annealed_gamma(state)))
    assert gammas[-1] > gammas[0]            # annealed up, not down
    assert gammas[-1] <= 1.0 + 1e-6
    # top-k on a fixed signal is strongly contractive: gamma ends well
    # above the conservative floor
    assert gammas[-1] > 10 * pipe.gamma_floor


def test_adaptive_gamma_through_sharded_engine():
    """make_block_step wires the base matrix into the pipeline, so
    comm_gamma="auto" works through the sharded path too (the launchers'
    route) and threads the delta EMA through comm_state."""
    from repro.core.sharded import make_block_step
    n = 6
    data = make_regression_problem(K=n, N=40, M=2, rho=0.1, seed=2)
    cfg = DiffusionConfig(num_agents=n, local_steps=2, step_size=0.02,
                          topology="ring", participation=0.9,
                          compress="topk", compress_ratio=0.25,
                          comm_gamma="auto")
    topo = cfg.make_topology()
    loss3 = lambda p, b, rng: data.loss_fn()(p, b)
    block_step = make_block_step(loss3, cfg, topology=topo)
    assert block_step.pipeline.adaptive
    step = jax.jit(block_step)
    sampler = make_block_sampler(data, T=2, batch=1)
    state = block_step.init_state(jnp.zeros((n, 2)))
    d0 = float(state.comm_state["delta"])
    for i in range(5):
        state, _ = step(state, sampler(jax.random.PRNGKey(10 + i)),
                        jax.random.PRNGKey(i))
    assert float(state.comm_state["delta"]) != d0
    assert np.isfinite(np.asarray(state.params)).all()


@pytest.mark.slow
def test_adaptive_gamma_beats_fixed_heuristic_msd():
    """Acceptance gate: comm_gamma="auto" beats the fixed heuristic's
    steady-state MSD on the compressed_diffusion preset."""
    n, M = 8, 20
    blocks = 1500
    data = make_regression_problem(K=n, N=100, M=M, rho=0.1, seed=6)
    prob = data.problem()
    qv = np.full(n, 0.8)
    w_o = prob.w_opt(qv)
    sampler = make_block_sampler(data, T=2, batch=1)
    msds = {}
    for label, gamma in (("fixed", None), ("auto", "auto")):
        spec = variants.compressed_diffusion(n, mu=0.01, T=2, q=0.8,
                                             compress="topk", ratio=0.1,
                                             gamma=gamma)
        eng = build(spec, data.loss_fn())
        _, _, hist = eng.run(jnp.zeros((n, M)), sampler, blocks, seed=0,
                             w_star=jnp.asarray(w_o))
        msds[label] = float(np.mean(hist[-blocks // 4:]))
    assert msds["auto"] < msds["fixed"], msds


# ---------------------------------------------------------------------------
# registry: third-party graph kinds plug in end-to-end
# ---------------------------------------------------------------------------

def test_registered_custom_graph_kind_builds_and_runs():
    """@GRAPHS.register kinds resolve through GraphSpec(kind=...) exactly
    like the built-ins (the examples/custom_graph.py mechanism)."""
    name = "always_full_TEST"
    if name not in GRAPHS:
        @GRAPHS.register(name)
        def _always_full(spec, topology, n):
            full = make_topology("full", n)
            return StaticGraph(full)

    data = make_regression_problem(K=4, N=20)
    spec = variants.vanilla_diffusion(4, mu=0.05).replace(
        graph=GraphSpec(kind=name))
    eng = build(spec, data.loss_fn())
    A = np.asarray(eng.graph.sample(None, KEY)[0])
    np.testing.assert_allclose(A, np.asarray(make_topology("full", 4).A),
                               atol=1e-6)
    sampler = make_block_sampler(data, T=1, batch=1)
    st = eng.init_state(jnp.zeros((4, 2)))
    st, _ = eng.step(st, sampler(KEY), jax.random.PRNGKey(2))
    assert np.isfinite(np.asarray(st.params)).all()
    # the CONFIG-STRING path reaches registered kinds too (dryrun --spec,
    # DiffusionEngine(cfg, loss) rebuilds): make_graph_process falls back
    # to the GRAPHS registry, and graph_kwargs carries every field for
    # non-built-in kinds so nothing is silently dropped
    dcfg = spec.replace(graph=GraphSpec(kind=name,
                                        drop=0.42)).to_diffusion_config()
    assert dict(dcfg.graph_kwargs)["drop"] == 0.42
    eng2 = DiffusionEngine(dcfg, data.loss_fn())
    A2 = np.asarray(eng2.graph.sample(None, KEY)[0])
    np.testing.assert_allclose(A2, A, atol=1e-6)
    # unknown kinds die with the registry's alternatives listed
    bad = spec.replace(graph=GraphSpec(kind="wormhole"))
    with pytest.raises(ValueError, match="registered graph"):
        build(bad, data.loss_fn())
    with pytest.raises(ValueError, match="GRAPHS"):
        make_graph_process("wormhole", make_topology("ring", 4))
