"""End-to-end integration: multi-device diffusion training in a subprocess
with forced host devices, checkpoint round-trip, data pipeline, optimizers."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.synthetic import lm_token_batch, make_regression_problem
from repro.models import transformer as tf
from repro.optim import adam, momentum, sgd

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("smollm_360m").smoke
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, params, step=7, metadata={"arch": "smoke"})
    restored, meta = load_checkpoint(path, params)
    assert meta["step"] == 7 and meta["arch"] == "smoke"
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    cfg = get_config("smollm_360m").smoke
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, params)
    bad = jax.tree.map(lambda x: jnp.zeros((3,) + x.shape, x.dtype), params)
    with pytest.raises(ValueError):
        load_checkpoint(path, bad)


def test_optimizers_reduce_loss():
    data = make_regression_problem(K=1, N=200, M=4, rho=0.01, seed=0)
    loss = data.loss_fn()
    u = jnp.asarray(data.U[0])
    d = jnp.asarray(data.d[0])
    for make_opt, lr in ((sgd, 0.05), (momentum, 0.02), (adam, 0.05)):
        opt = make_opt()
        w = jnp.zeros((4,))
        state = opt.init(w)
        l0 = float(loss(w, (u, d)))
        for _ in range(120):
            g = jax.grad(loss)(w, (u, d))
            upd, state = opt.update(g, state, w)
            w = w - lr * upd
        l1 = float(loss(w, (u, d)))
        assert l1 < 0.2 * l0, make_opt.__name__


def test_lm_token_batch_labels_shifted():
    b = lm_token_batch(jax.random.PRNGKey(0), (2, 16), 100)
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))


@pytest.mark.slow
def test_multidevice_block_step_subprocess():
    """Run the sharded block step on 8 forced host devices and verify it
    matches the single-device stacked engine bit-for-bit."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.diffusion import DiffusionConfig, DiffusionEngine
        from repro.core.sharded import make_block_step
        from repro.data.synthetic import make_regression_problem, make_block_sampler

        K = 8
        data = make_regression_problem(K=K, N=40, M=2, rho=0.1, seed=0)
        cfg = DiffusionConfig(num_agents=K, local_steps=2, step_size=0.02,
                              topology="ring", participation=0.7)
        topo = cfg.make_topology()
        A = jnp.asarray(topo.A, jnp.float32)
        loss3 = lambda p, b, rng: data.loss_fn()(p, b)
        mesh = jax.make_mesh((8,), ("data",))
        sampler = make_block_sampler(data, T=2, batch=2)
        batch = sampler(jax.random.PRNGKey(7))
        key = jax.random.PRNGKey(42)
        params = jax.random.normal(jax.random.PRNGKey(0), (K, 2))

        from repro.core.state import EngineState
        outs = {}
        for mix in ("dense", "sparse"):
            step = make_block_step(loss3, cfg, A, mix=mix,
                                   offsets=topo.neighbor_offsets_ring())
            p_shard = EngineState(NamedSharding(mesh, P("data", None)))
            with mesh:
                jstep = jax.jit(step,
                    in_shardings=(p_shard,
                                  jax.tree.map(lambda _: NamedSharding(
                                      mesh, P(None, "data")), batch),
                                  None),
                    out_shardings=(p_shard, None))
                st, m = jstep(EngineState(params), batch, key)
            outs[mix] = np.asarray(st.params)

        # reference: single-device stacked engine
        eng = DiffusionEngine(cfg, data.loss_fn())
        ref_state, _ = eng.step(eng.init_state(params), batch, key)
        for mix, got in outs.items():
            np.testing.assert_allclose(got, np.asarray(ref_state.params),
                                       rtol=1e-5, atol=1e-6, err_msg=mix)
        print("MULTIDEVICE_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=420)
    assert "MULTIDEVICE_OK" in res.stdout, res.stdout + res.stderr


@pytest.mark.slow
def test_train_driver_e2e_loss_decreases():
    """examples-style end-to-end: the training driver reduces loss."""
    prog = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.core.diffusion import DiffusionConfig
        from repro.core.sharded import make_block_step
        from repro.data.synthetic import lm_token_batch
        from repro.models import transformer as tf
        from repro.optim import adam

        cfg = get_config("smollm-360m").smoke
        K, T = 4, 2
        dcfg = DiffusionConfig(num_agents=K, local_steps=T, step_size=2e-3,
                               topology="ring", participation=0.9)
        topo = dcfg.make_topology()
        opt = adam()
        loss_fn = lambda p, b, r: tf.train_loss(p, cfg, b, remat=False)
        block_step = make_block_step(loss_fn, dcfg,
                                     jnp.asarray(topo.A, jnp.float32),
                                     mix="dense",
                                     grad_transform=opt.update)
        step = jax.jit(block_step)
        key = jax.random.PRNGKey(0)
        params = jax.vmap(lambda k: tf.init_params(k, cfg))(
            jax.random.split(key, K))
        state = block_step.init_state(params, opt.init(params))
        # FIXED dataset (memorization task) so loss genuinely decreases
        data = lm_token_batch(jax.random.PRNGKey(9), (T, K, 2, 32),
                              cfg.vocab_size)
        eval_loss = jax.jit(jax.vmap(
            lambda p, b: tf.train_loss(p, cfg, b, remat=False)))
        l0 = float(eval_loss(params, jax.tree.map(lambda x: x[0], data)).mean())
        for i in range(30):
            key, ks = jax.random.split(key)
            state, _ = step(state, data, ks)
        l1 = float(eval_loss(state.params,
                             jax.tree.map(lambda x: x[0], data)).mean())
        assert l1 < 0.7 * l0, (l0, l1)
        print("E2E_OK", l0, "->", l1)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=560)
    assert "E2E_OK" in res.stdout, res.stdout[-2000:] + res.stderr[-3000:]
