"""AdaptiveTrimMixer (core/mixing.py): MAD-fenced per-coordinate trimming
— planted outliers are removed up to the trim cap, honest data is left
untouched (no robustness tax: the no-attack MSD matches the linear
mixer), and under a sign-flip gradient attack the backend degrades to the
fixed trimmed mean's robustness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import AttackSpec, build
from repro.api.spec import MixerSpec
from repro.core import variants
from repro.core.mixing import AdaptiveTrimMixer, make_mixer
from repro.core.topology import make_topology
from repro.data.synthetic import make_block_sampler, make_regression_problem

KEY = jax.random.PRNGKey(0)


def test_no_outliers_is_plain_mean():
    """With nothing beyond the MAD fence every contributor keeps uniform
    weight — the aggregate is the plain mean over the active set."""
    K = 8
    x = np.linspace(-0.01, 0.01, K)[:, None] * np.ones((1, 3))
    x = x.astype(np.float32)
    mix = AdaptiveTrimMixer(K, trim=2, scope="global", mad_thresh=6.0)
    out = np.asarray(mix({"w": jnp.asarray(x)},
                         jnp.ones((K,), jnp.float32))["w"])
    np.testing.assert_allclose(out[0], x.mean(axis=0), atol=1e-6)


def test_exact_ties_never_flagged():
    """Strict fence inequalities: MAD = 0 on an exactly-tied majority
    must not flag the tied values themselves."""
    K = 6
    x = np.zeros((K, 2), np.float32)
    x[4] = 7.0                    # lone outlier against an all-zero majority
    mix = AdaptiveTrimMixer(K, trim=1, scope="global")
    out = np.asarray(mix({"w": jnp.asarray(x)},
                         jnp.ones((K,), jnp.float32))["w"])
    np.testing.assert_allclose(out[0], 0.0, atol=1e-7)


def test_planted_outlier_removed_up_to_cap():
    K = 8
    rng = np.random.default_rng(1)
    x = (rng.normal(0, 1e-3, (K, 4)) + 1.0).astype(np.float32)
    x[3] = 50.0
    active = jnp.ones((K,), jnp.float32)
    mix = AdaptiveTrimMixer(K, trim=2, scope="global")
    out = np.asarray(mix({"w": jnp.asarray(x)}, active)["w"])
    assert np.abs(out[0] - 1.0).max() < 0.1          # outlier gone
    # three outliers against cap 1: only one trimmed per side (agent 3 is
    # restored so the corrupted mass stays below the fence's breakdown point)
    x3 = x.copy()
    x3[3] = 1.0
    x3[0], x3[1], x3[2] = 50.0, 60.0, 70.0
    mix1 = AdaptiveTrimMixer(K, trim=1, scope="global")
    out3 = np.asarray(mix1({"w": jnp.asarray(x3)}, active)["w"])
    expect = np.sort(x3, axis=0)[:-1].mean(axis=0)   # top value dropped only
    np.testing.assert_allclose(out3[0], expect, atol=1e-4)


def test_neighborhood_dense_matches_gather_and_inactive_keep():
    K = 8
    topo = make_topology("ring", K)
    A = jnp.asarray(topo.A, jnp.float32)
    rng = np.random.default_rng(2)
    x = (rng.normal(0, 1e-2, (K, 3)) + 1.0).astype(np.float32)
    x[5] = -40.0
    params = {"w": jnp.asarray(x)}
    active = jnp.asarray(np.array([1, 1, 0, 1, 1, 1, 1, 0], np.float32))
    dense = AdaptiveTrimMixer(K, trim=1, scope="neighborhood")
    out_d = np.asarray(dense(params, active, A)["w"])
    gather = AdaptiveTrimMixer(K, trim=1, scope="neighborhood")
    gather.attach_neighbor_table(topo)
    out_g = np.asarray(gather(params, active, A)["w"])
    np.testing.assert_allclose(out_d, out_g, atol=1e-6)
    # inactive agents keep their iterate bit-exactly
    np.testing.assert_array_equal(out_d[2], x[2])
    np.testing.assert_array_equal(out_d[7], x[7])
    # agent 4 hears poisoned neighbor 5: the fence removes it
    assert np.abs(out_d[4] - 1.0).max() < 0.2


def test_make_mixer_wiring():
    K = 8
    topo = make_topology("ring", K)
    m = make_mixer("adaptive_trim", topo, num_agents=K, trim=2,
                   scope="neighborhood")
    assert isinstance(m, AdaptiveTrimMixer) and m._table is not None
    assert make_mixer("adaptive_trim", num_agents=K).scope == "global"
    with pytest.raises(ValueError, match="fused"):
        make_mixer("adaptive_trim", topo, num_agents=K,
                   scope="neighborhood", gather="fused")
    with pytest.raises(ValueError, match="mad_thresh"):
        AdaptiveTrimMixer(K, mad_thresh=0.0)


def _tail_msd(spec, data, w_o, blocks=500, tail=125):
    from repro.core.diffusion import network_msd
    eng = build(spec, data.loss_fn())
    K = spec.run.num_agents
    p0 = jnp.zeros((K, 2))
    state = eng.init_state(p0, eng.optimizer.init(p0))
    key = jax.random.PRNGKey(0)
    hist = []
    for i in range(blocks):
        key, kb, ks = jax.random.split(key, 3)
        state, _ = eng.step(state, sampler_cache(data)(kb), ks)
        if i >= blocks - tail:
            hist.append(float(network_msd(state.params, w_o)))
    return float(np.mean(hist))


_SAMPLERS = {}


def sampler_cache(data):
    if id(data) not in _SAMPLERS:
        _SAMPLERS[id(data)] = make_block_sampler(data, T=1, batch=1)
    return _SAMPLERS[id(data)]


@pytest.mark.slow
def test_no_attack_msd_matches_linear_mixer():
    """The no-robustness-tax gate: with no adversary the MAD fence flags
    (almost) nothing, so the adaptive trim's steady-state MSD stays within
    a tight band of the LINEAR dense mixer (measured ~0.87x at this
    setting — on small ring neighborhoods the occasional trim even
    reduces variance rather than adding a tax)."""
    K = 8
    data = make_regression_problem(K=K, N=100, M=2, rho=0.1, seed=7)
    w_o = jnp.asarray(data.problem().w_opt(np.full(K, 0.9)))
    base = variants.asynchronous_diffusion(K, mu=0.01, q=0.9)
    linear = _tail_msd(base, data, w_o)
    adaptive = _tail_msd(base.replace(
        mixer=MixerSpec(kind="adaptive_trim", trim=1,
                        scope="neighborhood")), data, w_o)
    assert adaptive < 1.25 * linear, (adaptive, linear)


@pytest.mark.slow
def test_sign_flip_attack_bounded_like_fixed_trim():
    """Under the bench_byzantine sign-flip setting the adaptive backend
    keeps honest agents bounded like the fixed trimmed mean (the
    corrupted coordinates blow through the fence and get trimmed)."""
    from repro.core.attacks import byzantine_indices
    K, blocks = 12, 350
    data = make_regression_problem(K=K, N=80, M=2, rho=0.1, seed=8,
                                   mean_scale=1.5, noise_low=0.01,
                                   noise_high=0.05, w_star_spread=0.5)
    w_o = data.problem().w_opt(None)
    sampler = make_block_sampler(data, T=1, batch=2)
    byz = byzantine_indices(K, 3)
    honest = [k for k in range(K) if k not in byz]

    def run(spec):
        eng = build(spec, data.loss_fn())
        p0 = jnp.zeros((K, 2))
        state = eng.init_state(p0, eng.optimizer.init(p0))
        key = jax.random.PRNGKey(0)
        for _ in range(blocks):
            key, kb, ks = jax.random.split(key, 3)
            state, _ = eng.step(state, sampler(kb), ks)
        p = np.asarray(state.params)
        return float(np.mean(np.sum((p[honest] - np.asarray(w_o)) ** 2,
                                    axis=1)))

    base = variants.byzantine_robust_diffusion(
        K, mu=0.05, num_byzantine=3, scale=3.0, mix="adaptive_trim")
    clean = run(base.replace(attack=AttackSpec(kind="none")))
    attacked = run(base)
    assert attacked < 20.0 * clean, (attacked, clean)
