"""Property-based guarantees of the compressors (via the tests/_hyp shim):
unbiasedness of the stochastic compressors and the bounded/vanishing
error-feedback residual that makes biased top-k convergent."""
import jax
import jax.numpy as jnp
import numpy as np

from _hyp import given, settings, st
from repro.core.compression import (ErrorFeedback, GaussianMask,
                                    Int8Stochastic, RandK, TopK)


def _vector(seed: int, n: int = 128, scale: float = 3.0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, (1, n)), jnp.float32)


def _mean_encoded(comp, x, n_keys: int, seed0: int) -> np.ndarray:
    def one(key):
        msgs, _ = comp.encode({"x": x}, (), key)
        return msgs["x"]
    keys = jax.random.split(jax.random.PRNGKey(seed0), n_keys)
    return np.asarray(jax.vmap(one)(keys)).mean(axis=0)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_int8_stochastic_unbiased(seed):
    """E[round_stochastic(x/s)*s] = x: the empirical mean over many keys
    converges to x within a few standard errors (per-coordinate rounding
    noise is at most one quantization step s = max|x|/127)."""
    x = _vector(seed)
    n_keys = 512
    mean = _mean_encoded(Int8Stochastic(), x, n_keys, seed + 1)
    step = float(jnp.abs(x).max()) / 127.0
    tol = 6.0 * step / np.sqrt(n_keys) + 1e-7
    np.testing.assert_allclose(mean, np.asarray(x), atol=tol)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_randk_unbiased(seed):
    """E[(n/k) mask * x] = x: the n/k rescale exactly cancels the k/n
    selection probability of the uniform subset."""
    n, ratio = 64, 0.25
    x = _vector(seed, n=n)
    n_keys = 4096
    mean = _mean_encoded(RandK(ratio), x, n_keys, seed + 1)
    # per-coordinate variance: x_i^2 (n/k - 1); tolerance at 6 sigma
    sd = np.abs(np.asarray(x)) * np.sqrt(1.0 / ratio - 1.0)
    tol = 6.0 * sd / np.sqrt(n_keys) + 1e-6
    assert (np.abs(mean - np.asarray(x)) <= tol).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_gaussian_mask_sigma0_is_randk(seed):
    x = _vector(seed, n=64)
    key = jax.random.PRNGKey(seed + 7)
    g, _ = GaussianMask(0.25, sigma=0.0).encode({"x": x}, (), key)
    r, _ = RandK(0.25).encode({"x": x}, (), key)
    np.testing.assert_allclose(np.asarray(g["x"]), np.asarray(r["x"]))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_topk_error_feedback_residual_vanishes(seed):
    """On a fixed vector sequence, top-k + EF has (a) uniformly bounded
    residual ||e_t|| and (b) time-averaged transmitted messages converging
    to the true signal at rate O(1/T) — the 'vanishing residual' property:
    every dropped coordinate is eventually retransmitted."""
    n, ratio, T = 64, 0.25, 200
    x = _vector(seed, n=n)
    comp = ErrorFeedback(TopK(ratio))
    state = comp.init_state({"x": x})
    total = np.zeros_like(np.asarray(x))
    norms = []
    for _ in range(T):
        msgs, state = comp.encode({"x": x}, state)
        total += np.asarray(msgs["x"])
        norms.append(float(jnp.linalg.norm(state["x"])))
    x_norm = float(jnp.linalg.norm(x)) + 1e-9
    # (a) bounded: the EF contraction keeps ||e_t|| <= ||x|| / delta with
    # delta = k/n; allow that worst case with slack
    assert max(norms) <= (2.0 / ratio) * x_norm
    # (b) vanishing: mean transmitted -> x  (error = e_T / T)
    mean_err = np.linalg.norm(total / T - np.asarray(x))
    assert mean_err <= max(norms) / T + 1e-6
    assert mean_err <= 0.05 * x_norm
