"""Section IV: Algorithm 1 reduces exactly to known algorithms.

The variants factories return declarative ExperimentSpecs; repro.api.build
materializes them (bit-identical to the legacy constructor path — asserted
in tests/test_api.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import build
from repro.core import variants
from repro.data.synthetic import make_block_sampler, make_regression_problem

K = 6


def _run(spec, data, blocks=40, seed=0):
    eng = build(spec, data.loss_fn())
    sampler = make_block_sampler(data, T=spec.run.local_steps, batch=1)
    params = jnp.zeros((K, 2))
    params, _, _ = eng.run(params, sampler, blocks, seed=seed)
    return np.asarray(params)


def test_fedavg_full_reduction():
    """q=1, A=(1/K)11^T: after every block, all agents hold the same model
    (eq. 39-40: exact average)."""
    data = make_regression_problem(K=K, N=50, seed=0)
    spec = variants.fedavg_full(K, T=3, mu=0.02)
    out = _run(spec, data)
    np.testing.assert_allclose(out, np.broadcast_to(out.mean(0), out.shape),
                               atol=1e-6)


def test_fedavg_manual_equivalence():
    """Algorithm 1 with fedavg topology == hand-rolled FedAvg, same seeds."""
    data = make_regression_problem(K=K, N=50, seed=1)
    spec = variants.fedavg_full(K, T=2, mu=0.05)
    eng = build(spec, data.loss_fn())
    sampler = make_block_sampler(data, T=2, batch=1)
    state = eng.init_state(jnp.zeros((K, 2)))
    loss_g = jax.vmap(jax.grad(data.loss_fn()))

    manual = jnp.zeros((K, 2))
    key = jax.random.PRNGKey(0)
    for i in range(10):
        key, kb, ks = jax.random.split(key, 3)
        batch = sampler(kb)
        state, _ = eng.step(state, batch, ks)
        # manual FedAvg with the same batches
        for t in range(2):
            bt = jax.tree.map(lambda x: x[t], batch)
            manual = manual - 0.05 * loss_g(manual, bt)
        manual = jnp.broadcast_to(manual.mean(0), manual.shape)
    np.testing.assert_allclose(np.asarray(state.params), np.asarray(manual),
                               rtol=1e-5, atol=1e-6)


def test_vanilla_diffusion_reduction():
    """T=1, q=1: Algorithm 1 == classical ATC diffusion, same seeds."""
    data = make_regression_problem(K=K, N=50, seed=2)
    spec = variants.vanilla_diffusion(K, mu=0.05, topology="ring")
    eng = build(spec, data.loss_fn())
    A = np.asarray(eng.topology.A, dtype=np.float32)
    sampler = make_block_sampler(data, T=1, batch=1)
    loss_g = jax.vmap(jax.grad(data.loss_fn()))

    state = eng.init_state(jnp.zeros((K, 2)))
    manual = jnp.zeros((K, 2))
    key = jax.random.PRNGKey(0)
    for i in range(10):
        key, kb, ks = jax.random.split(key, 3)
        batch = sampler(kb)
        state, _ = eng.step(state, batch, ks)
        bt = jax.tree.map(lambda x: x[0], batch)
        psi = manual - 0.05 * loss_g(manual, bt)          # adapt (eq. 44)
        manual = jnp.asarray(A).T @ psi                   # combine (eq. 45)
    np.testing.assert_allclose(np.asarray(state.params), np.asarray(manual),
                               rtol=1e-5, atol=1e-6)


def test_asynchronous_diffusion_is_T1(rng=None):
    data = make_regression_problem(K=K, N=50, seed=3)
    spec = variants.asynchronous_diffusion(K, mu=0.03, q=0.6)
    assert spec.run.local_steps == 1
    out = _run(spec, data, blocks=200)
    # converges near the drifted optimum
    w = data.problem().w_opt(np.full(K, 0.6))
    assert np.linalg.norm(out.mean(0) - w) < 0.3


def test_decentralized_fedavg_reduction():
    data = make_regression_problem(K=K, N=50, seed=4)
    spec = variants.decentralized_fedavg(K, T=4, mu=0.02)
    assert spec.run.local_steps == 4 and spec.participation.q == 1.0
    out = _run(spec, data, blocks=300)
    w = data.problem().w_opt(None)
    assert np.linalg.norm(out.mean(0) - w) < 0.3
