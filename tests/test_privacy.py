"""The privacy tier: RDP accounting, clip-and-noise, secure-agg wire
masks, epsilon-aware checkpoints, and the spec/CLI/build plumbing.

The load-bearing invariants:

* the jit accountant (:meth:`Privacy.advance`) is the numpy twin
  (:func:`rdp_increment_np`) accumulated at the realized rates, and both
  collapse to the closed-form Gaussian RDP ``alpha / (2 sigma^2)`` at
  full participation;
* the secure-agg masks telescope to zero — the masked combination equals
  the unmasked eq.-20 combination up to float accumulation, on the static
  graph AND under LinkDropout (per-block pairing re-derivation), with
  inactive receivers bit-exact;
* ``privacy_state`` rides the EngineState append-last contract: private
  checkpoints round-trip the accountant, and pre-privacy archives (the
  committed PR-8-era fixture) keep loading and continuing bit-identically.
"""
import argparse
import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.build import build
from repro.api.cli import add_spec_args, get_preset, spec_from_args
from repro.api.spec import (AsyncSpec, CompressionSpec, ExperimentSpec,
                            GraphSpec, OptimizerSpec, ParticipationSpec,
                            PrivacySpec, RunSpec)
from repro.checkpoint import load_experiment, save_experiment
from repro.core import privacy as priv
from repro.core.mixing import CommPipeline, make_mixer
from repro.core.msd import (compressor_injected_variance,
                            dp_injected_variance, theoretical_msd)
from repro.core.participation import masked_combination_np
from repro.core.serving import consensus_from_stacked
from repro.core.state import EngineState
from repro.core.topology import make_topology
from repro.data.synthetic import make_block_sampler, make_regression_problem

FIXTURE = Path(__file__).parent / "fixtures" / "pr8_engine_state.npz"


def _private_spec(K=4, *, nm=0.8, secure_agg=False, graph=None, **priv_kw):
    kw = dict(enabled=True, clip=1.0, noise_multiplier=nm,
              secure_agg=secure_agg)
    kw.update(priv_kw)
    return ExperimentSpec(
        graph=graph if graph is not None else GraphSpec(),
        participation=ParticipationSpec(kind="iid", q=0.8),
        privacy=PrivacySpec(**kw),
        run=RunSpec(num_agents=K, local_steps=1, step_size=0.05, blocks=4))


def _run_blocks(eng, data, state, n, *, key0=0):
    sampler = make_block_sampler(data, T=1, batch=1)
    metrics = None
    for i in range(n):
        state, metrics = eng.step(state, sampler(jax.random.PRNGKey(i)),
                                  jax.random.PRNGKey(100 + key0 + i))
    return state, metrics


# ---------------------------------------------------------------------------
# spec round-trip
# ---------------------------------------------------------------------------

def test_privacy_spec_json_roundtrip():
    spec = _private_spec(secure_agg=True, epsilon=4.0, delta=1e-6)
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    assert json.loads(spec.to_json())["privacy"]["secure_agg"] is True


# ---------------------------------------------------------------------------
# the accountant
# ---------------------------------------------------------------------------

def test_rdp_full_participation_closed_form():
    """q = 1 collapses the sampled-Gaussian bound to the plain Gaussian
    RDP alpha / (2 sigma^2)."""
    sigma = 2.0
    rdp = priv.rdp_increment_np(1.0, sigma)
    want = np.asarray(priv.DEFAULT_ORDERS, np.float64) / (2.0 * sigma ** 2)
    np.testing.assert_allclose(rdp, want, rtol=1e-10)


def test_accountant_jit_matches_numpy_twin():
    K, sigma = 5, 1.3
    p = priv.Privacy(num_agents=K, clip=1.0, noise_multiplier=sigma,
                     delta=1e-5)
    pstate = p.init_state()
    rng = np.random.default_rng(2)
    rdp_np = np.zeros(len(priv.DEFAULT_ORDERS), np.float64)
    for _ in range(7):
        active = (rng.random(K) < 0.7).astype(np.float32)
        pstate = p.advance(pstate, jnp.asarray(active))
        rdp_np += priv.rdp_increment_np(float(active.sum()) / K, sigma)
    np.testing.assert_allclose(np.asarray(pstate["rdp"]), rdp_np,
                               rtol=2e-4, atol=1e-6)
    assert int(pstate["steps"]) == 7
    eps_np = priv.epsilon_from_rdp_np(rdp_np, 1e-5)
    assert abs(float(p.epsilon(pstate)) - eps_np) < max(2e-3 * eps_np, 1e-3)
    assert abs(p.epsilon_np(pstate) - eps_np) < 1e-3


def test_accountant_zero_participation_is_free():
    p = priv.Privacy(num_agents=4, clip=1.0, noise_multiplier=1.0,
                     delta=1e-5)
    pstate = p.advance(p.init_state(), jnp.zeros((4,)))
    np.testing.assert_array_equal(np.asarray(pstate["rdp"]), 0.0)
    # zero accumulated RDP: epsilon sits at the order grid's conversion
    # floor (the Balle bound is not exactly 0 on a finite grid)
    floor = priv.epsilon_from_rdp_np(
        np.zeros(len(priv.DEFAULT_ORDERS)), 1e-5)
    assert float(p.epsilon(pstate)) == pytest.approx(floor, abs=1e-4)
    assert floor < 0.01


def test_calibration_spends_budget_tightly():
    eps, delta, q, steps = 5.0, 1e-5, 0.5, 300

    def spent(sigma):
        return priv.epsilon_from_rdp_np(
            steps * priv.rdp_increment_np(q, sigma), delta)

    nm = priv.calibrate_noise_multiplier(eps, delta, q, steps)
    assert spent(nm) <= eps + 1e-6
    assert spent(nm * 0.97) > eps          # minimal up to bisection width
    with pytest.raises(ValueError, match="must be > 0"):
        priv.calibrate_noise_multiplier(0.0, delta, q, steps)


def test_accountant_scales_with_local_steps():
    """T local steps per block = T mechanism invocations per block: the
    per-block increment is exactly T times the single-invocation bound
    (the review-critical factor — one increment per block would
    understate epsilon for any run with local_steps > 1)."""
    kw = dict(num_agents=4, clip=1.0, noise_multiplier=1.2, delta=1e-5)
    p1 = priv.Privacy(**kw)
    p3 = priv.Privacy(steps_per_block=3, **kw)
    active = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    s1 = p1.advance(p1.init_state(), active)
    s3 = p3.advance(p3.init_state(), active)
    np.testing.assert_allclose(np.asarray(s3["rdp"]),
                               3.0 * np.asarray(s1["rdp"]), rtol=1e-6)
    assert float(p3.epsilon(s3)) > float(p1.epsilon(s1))
    with pytest.raises(ValueError, match="steps_per_block"):
        priv.Privacy(steps_per_block=0, **kw)


def test_compile_privacy_accounts_local_steps():
    """Calibration composes over blocks * local_steps invocations, and
    the compiled tier carries the per-block invocation count."""
    spec = _private_spec(nm=0.0, epsilon=6.0).replace(
        run=RunSpec(num_agents=4, local_steps=2, step_size=0.05, blocks=4))
    p = priv.compile_privacy(spec)
    assert p.steps_per_block == 2
    spent = priv.epsilon_from_rdp_np(
        4 * 2 * priv.rdp_increment_np(0.8, p.noise_multiplier), p.delta)
    assert spent <= 6.0 + 1e-6
    # T=2 needs MORE noise than T=1 for the same budget over the same
    # number of blocks
    p1 = priv.compile_privacy(_private_spec(nm=0.0, epsilon=6.0))
    assert p.noise_multiplier > p1.noise_multiplier


def test_compile_privacy_rejects_heterogeneous_rates():
    """One tracked epsilon at the population rate is only a per-agent
    guarantee under a uniform rate — mixed-rate networks are rejected."""
    spec = _private_spec().replace(
        participation=ParticipationSpec(kind="iid",
                                        q=(1.0, 0.6, 0.8, 0.8)))
    with pytest.raises(ValueError, match="homogeneous participation"):
        priv.compile_privacy(spec)


def test_privacy_ctor_validation():
    with pytest.raises(ValueError, match="clip"):
        priv.Privacy(num_agents=4, clip=0.0, noise_multiplier=1.0,
                     delta=1e-5)
    with pytest.raises(ValueError, match="noise_multiplier"):
        priv.Privacy(num_agents=4, clip=1.0, noise_multiplier=0.0,
                     delta=1e-5)
    with pytest.raises(ValueError, match="delta"):
        priv.Privacy(num_agents=4, clip=1.0, noise_multiplier=1.0,
                     delta=1.0)


def test_compile_privacy_resolution():
    assert priv.compile_privacy(ExperimentSpec()) is None
    p = priv.compile_privacy(_private_spec(nm=1.5))
    assert p.noise_multiplier == 1.5 and p.epsilon_budget is None
    p = priv.compile_privacy(_private_spec(nm=0.0, epsilon=6.0))
    assert p.epsilon_budget == 6.0 and p.noise_multiplier > 0
    # calibrated sigma actually meets the budget over the spec's blocks
    spent = priv.epsilon_from_rdp_np(
        4 * priv.rdp_increment_np(0.8, p.noise_multiplier), p.delta)
    assert spent <= 6.0 + 1e-6
    with pytest.raises(ValueError, match="neither noise_multiplier nor "
                                         "epsilon"):
        priv.compile_privacy(_private_spec(nm=0.0, epsilon=0.0))


# ---------------------------------------------------------------------------
# clip-and-noise
# ---------------------------------------------------------------------------

def test_clip_and_noise_per_agent_global_norm():
    K = 3
    g = {"a": jnp.full((K, 2), 10.0), "b": jnp.full((K, 4), 10.0)}
    out = priv.clip_and_noise(g, jax.random.PRNGKey(0), clip=1.0,
                              noise_multiplier=0.0)
    sq = (np.asarray(out["a"]) ** 2).sum(1) + (np.asarray(out["b"]) ** 2).sum(1)
    np.testing.assert_allclose(np.sqrt(sq), 1.0, rtol=1e-5)
    # direction preserved: every coordinate scaled by the same factor
    np.testing.assert_allclose(np.asarray(out["a"]) / np.asarray(out["b"])[:, :2],
                               1.0, rtol=1e-5)
    # small gradients pass through untouched (scale = min(1, ...))
    small = {"a": jnp.asarray([[0.1, 0.2]])}
    out2 = priv.clip_and_noise(small, jax.random.PRNGKey(0), clip=1.0,
                               noise_multiplier=0.0)
    np.testing.assert_allclose(np.asarray(out2["a"]), [[0.1, 0.2]],
                               rtol=1e-6)
    # noise actually lands when the multiplier is positive
    out3 = priv.clip_and_noise(small, jax.random.PRNGKey(1), clip=1.0,
                               noise_multiplier=2.0)
    assert not np.allclose(np.asarray(out3["a"]), [[0.1, 0.2]], atol=1e-3)


def test_private_gradients_requires_counter_state():
    t = priv.PrivateGradients(1.0, 0.5).as_transform()
    g = jnp.ones((2, 3))
    with pytest.raises(ValueError, match="engine.optimizer.init"):
        t.update(g, None, g)


# ---------------------------------------------------------------------------
# secure-agg wire masks
# ---------------------------------------------------------------------------

def test_secure_agg_masks_cancel_exactly():
    K, M = 6, 5
    topo = make_topology("ring", K)
    A = jnp.asarray(topo.A, jnp.float32)
    stage = priv.make_secure_agg(K, seed=11, mask_scale=3.0)
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(K, M)), jnp.float32)
    for active_np in ([1.0] * K, [1, 0, 1, 1, 0, 1], [0.0] * K):
        active = jnp.asarray(active_np, jnp.float32)
        mixed = np.asarray(stage(X, active, A, jnp.uint32(2)))
        want = masked_combination_np(
            np.asarray(A), np.asarray(active)).T @ np.asarray(X)
        np.testing.assert_allclose(mixed, want, atol=5e-5)
        for k, a in enumerate(active_np):
            if not a:   # inactive receiver: unit column, bit-exact keep
                np.testing.assert_array_equal(mixed[k], np.asarray(X)[k])


def test_secure_agg_mask_stream_varies_by_block():
    """Different blocks draw different masks (fold_in on t) yet both
    cancel — the checkpoint/resume property of the mask epoch counter."""
    K = 4
    A = jnp.asarray(make_topology("ring", K).A, jnp.float32)
    stage = priv.make_secure_agg(K, seed=3)
    X = jnp.asarray(np.random.default_rng(1).normal(size=(K, 3)),
                    jnp.float32)
    ones = jnp.ones((K,), jnp.float32)
    want = np.asarray(A).T @ np.asarray(X)
    for t in (0, 1, 17):
        np.testing.assert_allclose(
            np.asarray(stage(X, ones, A, jnp.uint32(t))), want, atol=5e-5)


def test_secure_agg_rejects_single_agent():
    with pytest.raises(ValueError, match="num_agents >= 2"):
        priv.make_secure_agg(1)


@pytest.mark.parametrize("graph", [
    GraphSpec(),
    GraphSpec(kind="link_dropout", drop=0.3),
], ids=["static", "link_dropout"])
def test_secure_agg_engine_parity(graph):
    """Masked and unmasked runs of the SAME private experiment produce the
    same trajectory — the wire masks are invisible to the algorithm."""
    data = make_regression_problem(K=4, N=20)
    params = jnp.zeros((4, 2))
    out = {}
    for sa in (False, True):
        spec = _private_spec(nm=0.7, secure_agg=sa, graph=graph)
        eng = build(spec, data.loss_fn())
        state = eng.init_state(params, eng.optimizer.init(params),
                               key=jax.random.PRNGKey(5))
        state, _ = _run_blocks(eng, data, state, 3)
        out[sa] = np.asarray(state.params)
    np.testing.assert_allclose(out[True], out[False], atol=5e-5)


def test_pipeline_secure_agg_guards():
    from repro.core import compression as comp
    topo = make_topology("ring", 4)
    stage = priv.make_secure_agg(4)
    dense = make_mixer("dense", topo, num_agents=4)
    with pytest.raises(ValueError, match="identity-mode"):
        CommPipeline(dense, comp.Int8Stochastic(), secure_agg=stage)
    with pytest.raises(ValueError, match="no wire to mask"):
        CommPipeline(make_mixer("none", topo, num_agents=4),
                     secure_agg=stage)
    with pytest.raises(ValueError, match="linear"):
        CommPipeline(make_mixer("trimmed_mean", topo, num_agents=4),
                     secure_agg=stage)
    # the happy path carries the mask-epoch counter in comm_state
    pipe = CommPipeline(dense, secure_agg=stage)
    assert pipe.stateful
    assert int(pipe.init_state(jnp.zeros((4, 2)))["t"]) == 0


# ---------------------------------------------------------------------------
# build() composition guards
# ---------------------------------------------------------------------------

def test_build_rejects_privacy_plus_explicit_transform():
    data = make_regression_problem(K=4, N=20)
    from repro.optim.optimizers import sgd
    with pytest.raises(ValueError, match="explicit grad_transform"):
        build(_private_spec(), data.loss_fn(), grad_transform=sgd())


def test_build_gauss_compression_needs_opt_in():
    data = make_regression_problem(K=4, N=20)
    spec = _private_spec().replace(
        compression=CompressionSpec(kind="gauss", ratio=1.0, sigma=0.05))
    with pytest.raises(ValueError, match="double-noises"):
        build(spec, data.loss_fn())
    spec = dataclasses.replace(
        spec, privacy=dataclasses.replace(spec.privacy, allow_gauss=True))
    eng = build(spec, data.loss_fn())   # explicit opt-in builds fine
    assert eng.privacy is not None


def test_build_rejects_async_secure_agg():
    data = make_regression_problem(K=4, N=20)
    spec = _private_spec(secure_agg=True).replace(
        asynchrony=AsyncSpec(enabled=True))
    with pytest.raises(ValueError, match="secure-agg"):
        build(spec, data.loss_fn())


# ---------------------------------------------------------------------------
# engine threading: metrics, state, resume guard
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("asynchronous", [False, True],
                         ids=["stacked", "async"])
def test_engine_threads_accountant(asynchronous):
    data = make_regression_problem(K=4, N=20)
    spec = _private_spec(nm=1.0)
    if asynchronous:
        spec = spec.replace(asynchrony=AsyncSpec(enabled=True))
    eng = build(spec, data.loss_fn())
    params = jnp.zeros((4, 2))
    state = eng.init_state(params, eng.optimizer.init(params),
                           key=jax.random.PRNGKey(0))
    assert state.privacy_state is not None
    sampler = make_block_sampler(data, T=1, batch=1)
    eps = []
    for i in range(4):
        state, m = eng.step(state, sampler(jax.random.PRNGKey(i)),
                            jax.random.PRNGKey(10 + i))
        eps.append(float(m["epsilon"]))
    assert eps == sorted(eps)              # spent epsilon is monotone
    assert eps[-1] > 0
    assert int(state.privacy_state["steps"]) == 4
    # the metric agrees with the accountant read off the state
    assert abs(eps[-1] - float(eng.privacy.epsilon(state.privacy_state))) \
        < 1e-6


def test_engine_accountant_counts_local_steps():
    """End to end: a local_steps=2 engine accumulates TWICE the realized
    single-invocation RDP per block (PrivateGradients draws fresh noise
    at every local step inside the scan)."""
    data = make_regression_problem(K=4, N=20)
    spec = _private_spec(nm=1.0).replace(
        run=RunSpec(num_agents=4, local_steps=2, step_size=0.05, blocks=4))
    eng = build(spec, data.loss_fn())
    assert eng.privacy.steps_per_block == 2
    params = jnp.zeros((4, 2))
    state = eng.init_state(params, eng.optimizer.init(params),
                           key=jax.random.PRNGKey(0))
    sampler = make_block_sampler(data, T=2, batch=1)
    rdp = np.zeros(len(priv.DEFAULT_ORDERS), np.float64)
    for i in range(3):
        state, m = eng.step(state, sampler(jax.random.PRNGKey(i)),
                            jax.random.PRNGKey(10 + i))
        q = float(np.asarray(m["active"]).sum()) / 4
        rdp += 2 * priv.rdp_increment_np(q, 1.0)
    np.testing.assert_allclose(np.asarray(state.privacy_state["rdp"]),
                               rdp, rtol=2e-4, atol=1e-6)


def test_step_rejects_missing_privacy_state():
    """A checkpoint from a non-private run cannot resume under a
    PrivacySpec without a fresh accountant — the append-last guard."""
    data = make_regression_problem(K=4, N=20)
    eng = build(_private_spec(), data.loss_fn())
    params = jnp.zeros((4, 2))
    state = eng.init_state(params, eng.optimizer.init(params),
                           key=jax.random.PRNGKey(0))
    bad = state.replace(privacy_state=None)
    sampler = make_block_sampler(data, T=1, batch=1)
    with pytest.raises(ValueError, match="fresh accountant"):
        eng.step(bad, sampler(jax.random.PRNGKey(0)),
                 jax.random.PRNGKey(1))


# ---------------------------------------------------------------------------
# CLI flags, guard, preset
# ---------------------------------------------------------------------------

def _parse(argv):
    # a FRESH parser per parse: add_spec_args shares one _explicit set per
    # parser instance, and the launchers parse exactly once
    ap = argparse.ArgumentParser()
    add_spec_args(ap)
    return ap.parse_args(argv)


def test_cli_privacy_flags_map_to_spec():
    spec = spec_from_args(_parse(
        ["--privacy", "--privacy-epsilon", "4.0", "--privacy-clip", "0.5",
         "--privacy-secure-agg"]))
    assert spec.privacy == PrivacySpec(enabled=True, epsilon=4.0,
                                       clip=0.5, secure_agg=True)
    assert spec_from_args(_parse([])).privacy == PrivacySpec()


def test_cli_privacy_subflags_require_privacy():
    with pytest.raises(ValueError, match="privacy is not enabled"):
        spec_from_args(_parse(["--privacy-epsilon", "4.0"]))
    with pytest.raises(ValueError, match="privacy is not enabled"):
        spec_from_args(_parse(["--privacy-secure-agg"]))


def test_cli_private_diffusion_preset():
    spec = spec_from_args(_parse(
        ["--preset", "private_diffusion", "--agents", "4"]))
    assert spec.privacy.enabled and spec.privacy.secure_agg
    assert spec.privacy.epsilon == 8.0
    # sub-flags overlay the preset without needing --privacy (the preset
    # already enables the tier)
    spec = spec_from_args(_parse(
        ["--preset", "private_diffusion", "--agents", "4",
         "--privacy-noise", "2.0"]))
    assert spec.privacy.noise_multiplier == 2.0
    # and the preset's spec actually builds a private engine
    factory = get_preset("private_diffusion")
    data = make_regression_problem(K=4, N=20)
    eng = build(factory(K=4, T=1, mu=0.05, q=0.8, corr=0.0, num_groups=2),
                data.loss_fn())
    assert eng.privacy is not None and eng.privacy.secure_agg


# ---------------------------------------------------------------------------
# epsilon-aware checkpoints
# ---------------------------------------------------------------------------

def test_private_checkpoint_roundtrips_accountant(tmp_path):
    data = make_regression_problem(K=4, N=20)
    spec = _private_spec(nm=1.0, epsilon=50.0)
    eng = build(spec, data.loss_fn())
    params = jnp.zeros((4, 2))
    state = eng.init_state(params, eng.optimizer.init(params),
                           key=jax.random.PRNGKey(0))
    state, _ = _run_blocks(eng, data, state, 3)
    eps = eng.privacy.epsilon_np(state.privacy_state)
    assert eps > 0
    path = str(tmp_path / "private.npz")
    save_experiment(path, state, spec=spec, step=3,
                    metadata={"epsilon_spent": eps,
                              "privacy_delta": spec.privacy.delta})
    like = jax.tree.map(jnp.zeros_like, state)
    loaded, meta = load_experiment(path, like)
    np.testing.assert_array_equal(np.asarray(loaded.privacy_state["rdp"]),
                                  np.asarray(state.privacy_state["rdp"]))
    assert int(loaded.privacy_state["steps"]) == 3
    assert meta["epsilon_spent"] == pytest.approx(eps)
    assert meta["privacy_delta"] == spec.privacy.delta
    # the restored accountant keeps spending from where it left off
    cont, m = _run_blocks(eng, data, loaded, 1, key0=3)
    assert eng.privacy.epsilon_np(cont.privacy_state) > eps


def test_pr8_checkpoint_loads_and_continues_bit_identically(tmp_path):
    """The committed pre-privacy archive (no privacy_state key — None
    leaves are never serialized) loads into today's EngineState and
    continues exactly as a freshly saved checkpoint does: the append-last
    field contract, locked against a real artifact."""
    data = make_regression_problem(K=4, N=20, seed=3)
    spec = ExperimentSpec(
        optimizer=OptimizerSpec(kind="momentum"),
        participation=ParticipationSpec(kind="iid", q=0.9),
        run=RunSpec(num_agents=4, local_steps=1, step_size=0.05, blocks=5))
    eng = build(spec, data.loss_fn())
    params = jnp.zeros((4, 2))
    state = eng.init_state(params, eng.optimizer.init(params),
                           key=jax.random.PRNGKey(7))
    sampler = make_block_sampler(data, T=1, batch=2)
    for i in range(3):
        state, _ = eng.step(state, sampler(jax.random.PRNGKey(i)),
                            jax.random.PRNGKey(50 + i))
    # the fixture holds exactly the pre-privacy leaf set
    with np.load(FIXTURE) as z:
        assert not any(k.startswith("privacy_state") for k in z.files)
        assert any(k.startswith("params") for k in z.files)
    fresh = str(tmp_path / "now.npz")
    save_experiment(fresh, state, spec=spec, step=3)
    like = jax.tree.map(jnp.zeros_like, state)
    from_fixture, _ = load_experiment(str(FIXTURE), like)
    from_fresh, _ = load_experiment(fresh, like)
    for a, b in zip(jax.tree.leaves(from_fixture),
                    jax.tree.leaves(from_fresh)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # ... and both continue bit-identically under the rebuilt engine
    conts = []
    for start in (from_fixture, from_fresh):
        s = start
        for i in range(3, 5):
            s, _ = eng.step(s, sampler(jax.random.PRNGKey(i)),
                            jax.random.PRNGKey(50 + i))
        conts.append(np.asarray(s.params))
    np.testing.assert_array_equal(conts[0], conts[1])


# ---------------------------------------------------------------------------
# serving: freshness-weighted consensus
# ---------------------------------------------------------------------------

def test_consensus_freshness_weights():
    K = 4
    stacked = jnp.arange(K * 3, dtype=jnp.float32).reshape(K, 3)
    x = np.asarray(stacked)
    w = np.array([0.0, 1.0, 3.0, 0.0], np.float32)
    out = consensus_from_stacked(stacked, K, weights=w)
    np.testing.assert_allclose(np.asarray(out),
                               (x[1] + 3.0 * x[2]) / 4.0, rtol=1e-6)
    # all-zero weights degrade to the uniform mean, not NaN
    out0 = consensus_from_stacked(stacked, K, weights=np.zeros(K))
    np.testing.assert_allclose(np.asarray(out0), x.mean(0), rtol=1e-6)
    with pytest.raises(ValueError, match="order statistic"):
        consensus_from_stacked(stacked, K, mix="trimmed_mean", weights=w)
    with pytest.raises(ValueError, match="shape"):
        consensus_from_stacked(stacked, K, weights=np.ones(K + 1))


def test_freshness_weights_from_async_discount():
    """The serving path weighs agents by the engine's own age-discount
    law: a fully fresh clock vector reproduces the uniform consensus."""
    data = make_regression_problem(K=4, N=20)
    spec = ExperimentSpec(
        asynchrony=AsyncSpec(enabled=True),
        run=RunSpec(num_agents=4, local_steps=1, step_size=0.05, blocks=2))
    eng = build(spec, data.loss_fn())
    ages = jnp.asarray([0.0, 2.0, 5.0, 0.0])
    w = np.asarray(eng._discount(ages))
    assert w[0] == w[3] == w.max()
    assert w[1] > w[2]                      # staler -> smaller weight
    stacked = jnp.asarray(np.random.default_rng(0).normal(size=(4, 3)),
                          jnp.float32)
    out = consensus_from_stacked(stacked, 4,
                                 weights=eng._discount(jnp.zeros(4)))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(stacked).mean(0), rtol=1e-5)


# ---------------------------------------------------------------------------
# Theorem-5 surrogate: injected variance
# ---------------------------------------------------------------------------

def test_injected_variance_helpers():
    assert dp_injected_variance(2.0, 3.0) == pytest.approx(36.0)
    assert dp_injected_variance(1.0, 0.0) == 0.0
    # randk: omega = 1/r - 1, weighted by participation and signal power
    assert compressor_injected_variance(
        "randk", ratio=0.25, signal_power=2.0, q=0.5) == pytest.approx(3.0)
    v = compressor_injected_variance("gauss", ratio=1.0, sigma=0.1,
                                     signal_power=4.0, q=1.0)
    assert v == pytest.approx(0.04)
    with pytest.raises(ValueError):
        compressor_injected_variance("topk", ratio=0.25, signal_power=1.0)


def test_theoretical_msd_injected_variance_is_linear():
    data = make_regression_problem(K=4, N=50, M=2, seed=0)
    topo = make_topology("ring", 4)
    kw = dict(A=topo.A, q=np.full(4, 0.8), mu=0.01, T=1)
    base = theoretical_msd(data.problem(), **kw)["msd"]
    m1 = theoretical_msd(data.problem(), injected_variance=0.5, **kw)["msd"]
    m2 = theoretical_msd(data.problem(), injected_variance=1.0, **kw)["msd"]
    assert base < m1 < m2
    # the injected term enters S_noise linearly at fixed operators
    np.testing.assert_allclose(m2 - base, 2.0 * (m1 - base), rtol=1e-4)
    # per-agent (K,) vectors are accepted; negatives are not
    mv = theoretical_msd(data.problem(),
                         injected_variance=np.full(4, 0.5), **kw)["msd"]
    assert mv == pytest.approx(m1, rel=1e-6)
    with pytest.raises(ValueError):
        theoretical_msd(data.problem(), injected_variance=-1.0, **kw)
