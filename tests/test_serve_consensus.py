"""Serving-side consensus extraction (launch/serve.py): the fixed
hard-coded-FedAvg bug — consensus now comes from the checkpoint spec's
topology through the trained mixer backend, time-varying-graph specs warn,
and the legacy (spec-less) default stays bit-identical."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_mixer, make_topology
from repro.launch.serve import consensus_from_stacked

KEY = jax.random.PRNGKey(0)


def _stacked(K):
    ks = jax.random.split(KEY, 2)
    return {"w": jax.random.normal(ks[0], (K, 4, 3)),
            "b": jax.random.normal(ks[1], (K, 2))}


def test_default_path_bit_identical_to_legacy():
    """topology=None (spec-less checkpoints): one all-active FedAvg step,
    exactly the pre-fix behavior."""
    K = 6
    stacked = _stacked(K)
    topo = make_topology("fedavg", K)
    mixer = make_mixer("dense", topo, num_agents=K)
    legacy = jax.tree.map(
        lambda x: x[0],
        mixer(stacked, jnp.ones((K,), jnp.float32),
              jnp.asarray(topo.A, jnp.float32)))
    out = consensus_from_stacked(stacked, K, "dense")
    for a, b in zip(jax.tree.leaves(legacy), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("mix", ["dense", "sparse", "pallas"])
def test_spec_topology_reaches_network_mean(mix):
    """Linear mixers over the spec's (non-fedavg) topology iterate the
    combination step to the exact network mean — including the sparse
    backend, whose circulant offsets now come from the REAL base graph
    instead of the fedavg stand-in."""
    K = 8
    stacked = _stacked(K)
    ring = make_topology("ring", K)
    kwargs = {"topology": ring}
    out = consensus_from_stacked(stacked, K, mix, **kwargs)
    for leaf, o in zip(jax.tree.leaves(stacked), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(o),
                                   np.asarray(leaf).mean(0),
                                   atol=1e-4, err_msg=mix)


def test_robust_scopes_over_spec_topology():
    """Global robust aggregation applies once (idempotent); the
    neighborhood scope aggregates over the trained ring structure and
    still suppresses an outlier agent."""
    K = 8
    ring = make_topology("ring", K)
    vals = jax.random.normal(KEY, (K, 3)) * 0.1
    vals = vals.at[2].set(50.0)                     # poisoned agent
    for scope in ("global", "neighborhood"):
        out = consensus_from_stacked({"w": vals}, K, "trimmed_mean",
                                     trim=1, scope=scope, topology=ring)
        assert float(jnp.abs(out["w"]).max()) < 1.0, scope


def test_engine_state_input_uses_param_stack():
    """Async-engine checkpoints hand the whole EngineState to serving:
    consensus must come from the param stack alone and match the bare-stack
    call bit for bit — clocks/staleness buffers are not averageable."""
    from repro.core.state import EngineState

    K = 6
    stacked = _stacked(K)
    want = consensus_from_stacked(stacked, K, "dense")
    state = EngineState(params=stacked, opt_state=(),
                        async_state={"t_local": jnp.zeros((K,)),
                                     "ages": jnp.zeros((K, 3))})
    got = consensus_from_stacked(state, K, "dense")
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # dict-shaped EngineState (hand-built archive views) routes the same way
    got2 = consensus_from_stacked(
        {"params": stacked, "async_state": {"t_local": jnp.zeros((K,))}},
        K, "dense")
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_single_model_checkpoint_unchanged():
    """K = 1 (plain checkpoints) stays the identity."""
    params = {"w": jax.random.normal(KEY, (1, 3))}
    out = consensus_from_stacked(params, 1, "dense")
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(params["w"][0]))


def test_serve_spec_checkpoint_uses_spec_topology_and_warns(tmp_path):
    """End-to-end through launch.serve.load_params: a spec checkpoint
    trained on a ring + link-dropout graph extracts its consensus over the
    ring (not fedavg) and warns that the dynamic graph is approximated by
    its base topology."""
    import argparse

    from repro.api import ModelSpec, build
    from repro.api.cli import add_spec_args
    from repro.checkpoint import save_experiment
    from repro.core import variants
    from repro.launch import serve

    K = 4
    spec = variants.link_dropout_diffusion(K, mu=0.02, drop=0.3).replace(
        model=ModelSpec(kind="transformer", arch="smollm-360m", smoke=True))
    eng = build(spec)
    params = eng.init_params(jax.random.PRNGKey(0))
    state = eng.init_state(params)
    path = str(tmp_path / "ring_ckpt.npz")
    save_experiment(path, state, spec=spec, step=1)

    ap = argparse.ArgumentParser()
    add_spec_args(ap)
    ap.add_argument("--checkpoint", default=None)
    ap.set_defaults(agents=1)
    args = ap.parse_args(["--checkpoint", path])
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        got, cfg = serve.load_params(args, jax.random.PRNGKey(1))
    assert any("time-varying" in str(w.message) for w in caught)
    # consensus == the network mean over the ring (dense mixer, iterated)
    for leaf, o in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(leaf, np.float32).mean(0),
                                   atol=1e-2)
