"""Data pipeline: partitioners + deterministic block iteration."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.data.pipeline import (BlockIterator, TokenDataset,
                                 contiguous_partition, dirichlet_partition)


def test_dirichlet_partition_covers_everything():
    labels = np.repeat(np.arange(10), 100)
    parts = dirichlet_partition(labels, K=8, alpha=0.5, seed=0)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)  # exact partition
    assert all(len(p) >= 1 for p in parts)


def test_dirichlet_alpha_controls_heterogeneity():
    labels = np.repeat(np.arange(10), 200)

    def label_entropy(parts):
        ents = []
        for p in parts:
            counts = np.bincount(labels[p], minlength=10) + 1e-9
            pr = counts / counts.sum()
            ents.append(-(pr * np.log(pr)).sum())
        return np.mean(ents)

    iid_ent = label_entropy(dirichlet_partition(labels, 8, alpha=100.0, seed=1))
    skew_ent = label_entropy(dirichlet_partition(labels, 8, alpha=0.05, seed=1))
    assert skew_ent < iid_ent  # small alpha => agents see fewer classes


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(10, 300))
def test_contiguous_partition_property(K, n):
    parts = contiguous_partition(n, K)
    assert len(parts) == K
    cat = np.concatenate(parts)
    np.testing.assert_array_equal(cat, np.arange(n))


def test_block_iterator_shapes_and_determinism():
    ds = TokenDataset.synthetic(vocab=256, n_tokens=10_000, seq_len=32, seed=0)
    parts = contiguous_partition(ds.num_windows, 4)
    it = BlockIterator(ds, parts, local_steps=3, per_agent_batch=2, seed=7)
    b1 = it.block(5)
    b2 = it.block(5)
    assert b1["tokens"].shape == (3, 4, 2, 32)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["tokens"])[..., 1:],
                                  np.asarray(b1["labels"])[..., :-1])
    # different blocks differ
    b3 = it.block(6)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_block_iterator_respects_partitions():
    """Agent k's tokens must come from agent k's windows only."""
    ds = TokenDataset.synthetic(vocab=256, n_tokens=5_000, seq_len=16, seed=1)
    parts = contiguous_partition(ds.num_windows, 2)
    it = BlockIterator(ds, parts, local_steps=2, per_agent_batch=4, seed=0)
    batch = np.asarray(it.block(0)["tokens"])
    windows = {k: {ds.window(int(w))[0].tobytes() for w in parts[k]}
               for k in range(2)}
    for k in range(2):
        for t in range(2):
            for b in range(4):
                assert batch[t, k, b].tobytes() in windows[k]


def test_pipeline_feeds_engine():
    """End-to-end: pipeline -> sharded block step on an LM."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.diffusion import DiffusionConfig
    from repro.core.sharded import make_block_step
    from repro.models import transformer as tf

    cfg = get_config("smollm-360m").smoke
    K, T = 4, 2
    ds = TokenDataset.synthetic(vocab=cfg.vocab_size, n_tokens=20_000,
                                seq_len=32, seed=0)
    parts = contiguous_partition(ds.num_windows, K)
    it = BlockIterator(ds, parts, local_steps=T, per_agent_batch=2, seed=0)
    dcfg = DiffusionConfig(num_agents=K, local_steps=T, step_size=1e-2,
                           topology="ring", participation=0.9)
    topo = dcfg.make_topology()
    block_step = make_block_step(
        lambda p, b, r: tf.train_loss(p, cfg, b, remat=False), dcfg,
        jnp.asarray(topo.A, jnp.float32), mix="dense")
    step = jax.jit(block_step)
    params = jax.vmap(lambda k: tf.init_params(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), K))
    state, _ = step(block_step.init_state(params), it.block(0),
                    jax.random.PRNGKey(1))
    for leaf in jax.tree.leaves(state.params):
        assert not bool(jnp.isnan(leaf).any())
